"""RL5 — exception hygiene: no silent swallows, no dropped task handles.

The failure plane of ISSUE 10 only works when errors *surface*: a crashed
replica must feed the health state machine, a wedged solve must trip the
watchdog, a poisoned request must resolve its future with an error.  Every
silently-discarded exception is a hole in that accounting, and the three
shapes this rule flags are exactly the holes that hide fault-injection
regressions:

* **bare ``except:``** — catches ``SystemExit`` / ``KeyboardInterrupt`` /
  ``asyncio.CancelledError`` along with everything else; a cancelled
  dispatcher drain or a watchdog abandonment can be eaten by one of these
  and the server wedges instead of shutting down.  Flagged regardless of
  the handler body: even a re-raising bare except should name what it
  catches (``except BaseException:``).
* **broad silent swallow** — an ``except Exception`` / ``except
  BaseException`` handler (directly or inside a tuple) whose body does
  nothing: only ``pass`` / ``continue`` / ``...``.  The error leaves no
  record anywhere — no metric, no event, no log, no re-raise.  Handlers
  naming *specific* exception types (``except asyncio.TimeoutError:
  pass`` — the flush-timer wait idiom) are a legitimate pattern and do
  not fire.
* **dropped ``create_task`` result** — a ``create_task(...)`` call used
  as a bare expression statement.  The event loop keeps only a weak
  reference to tasks: the handle can be collected mid-flight, and its
  exception is reported only at GC time ("Task exception was never
  retrieved"), long after the failure mattered.  Keep the handle and
  attach a done-callback or await it — the ``AsyncServer._batch_tasks``
  pattern (strong set + ``add_done_callback`` that both retrieves the
  exception and discards the reference).

Escape hatch: ``# rl5: swallow-ok — <reason>`` on the offending line (or
the line above) for sites where discarding really is the contract, e.g. a
best-effort cleanup path whose failure has no one left to tell.
"""

from __future__ import annotations

import ast
from typing import Iterable

from tools.reprolint.checkers.common import dotted
from tools.reprolint.core import Checker, Context, Finding

#: Exception leaves broad enough that silently eating them hides real bugs.
BROAD_TYPES = {"Exception", "BaseException"}


def _type_leaves(type_node: ast.AST | None) -> list[str]:
    """Leaf names of the exception types a handler catches ([] for bare)."""
    if type_node is None:
        return []
    elts = type_node.elts if isinstance(type_node, ast.Tuple) else [type_node]
    return [dotted(e).rpartition(".")[2] for e in elts]


def _is_silent(body: list[ast.stmt]) -> bool:
    """True when the handler body discards the exception without a trace."""
    for stmt in body:
        if isinstance(stmt, (ast.Pass, ast.Continue)):
            continue
        if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Constant):
            continue  # docstring or `...`
        return False
    return True


class ExceptionHygieneChecker(Checker):
    """RL5: bare excepts, broad silent swallows, dropped create_task handles."""

    rule_id = "RL5"
    title = "exception hygiene"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        findings: list[Finding] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ExceptHandler):
                findings.extend(self._check_handler(ctx, node))
            elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
                findings.extend(self._check_dropped_task(ctx, node))
        return findings

    def _check_handler(self, ctx: Context, node: ast.ExceptHandler):
        if node.type is None:
            yield self.finding(
                ctx, node,
                "bare `except:` catches SystemExit/KeyboardInterrupt/"
                "CancelledError too — name the types "
                "(`except Exception:` at the broadest)",
            )
            return
        caught = _type_leaves(node.type)
        broad = [t for t in caught if t in BROAD_TYPES]
        if broad and _is_silent(node.body):
            # Anchored on the body (the `pass`): the escape marker reads
            # naturally either there or on the `except` line above.
            yield self.finding(
                ctx, node.body[0],
                f"`except {broad[0]}` silently swallows the error (body is "
                f"only pass/continue/...): record it, re-raise it, or "
                f"narrow the type",
            )

    def _check_dropped_task(self, ctx: Context, node: ast.Expr):
        call = node.value
        if dotted(call.func).rpartition(".")[2] != "create_task":
            return
        yield self.finding(
            ctx, node,
            "`create_task(...)` result dropped: the loop holds only a weak "
            "reference and the task's exception is never retrieved — keep "
            "the handle and add_done_callback (see AsyncServer._batch_tasks) "
            "or await it",
        )
