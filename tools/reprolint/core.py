"""reprolint framework: findings, checker plugin API, suppressions, baseline.

A checker is a class with a ``rule_id`` and a ``visit(ctx)`` method returning
``Finding`` objects; ``Context`` hands it the parsed AST, the raw source, and
a tokenize-derived per-line comment map (AST alone drops comments, and the
``# guarded-by:`` / ``# lock-ok:`` conventions live in comments).

Suppression and baseline handling are centralized here so individual checkers
only ever emit; ``run_paths`` filters.
"""

from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import tokenize
from pathlib import Path
from typing import Iterable, Iterator

RULES = ("RL1", "RL2", "RL3", "RL4", "RL5")

# Per-rule escape-hatch comment markers (line-level, reason required).
ESCAPE_MARKERS = {
    "RL1": "trace-ok:",
    "RL2": "packed-ok:",
    "RL3": "lock-ok:",
    "RL4": "future-ok:",
    "RL5": "rl5: swallow-ok",
}

DISABLE_MARKER = "reprolint: disable="

# Directories never scanned: build residue plus the deliberately-dirty
# selftest fixtures (they exist to make rules fire).
EXCLUDED_DIR_NAMES = {"__pycache__", ".git", ".pytest_cache", ".hypothesis", "selftest"}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    file: str
    line: int
    rule_id: str
    message: str

    def fingerprint(self, source_line: str = "") -> str:
        """Stable id for baselining: path + rule + normalized line text.

        Deliberately excludes the line *number* so unrelated edits above a
        grandfathered finding do not un-baseline it.
        """
        key = f"{self.file}::{self.rule_id}::{source_line.strip()}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.rule_id} {self.message}"


class Context:
    """Everything a checker may inspect about one source file."""

    def __init__(self, path: Path, rel: str, source: str):
        self.path = path
        self.rel = rel
        self.source = source
        self.tree = ast.parse(source, filename=rel)
        self.lines = source.splitlines()
        self.comments = _comment_map(source)
        self._block_suppressed = _block_suppressions(self.tree, self.comments)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def comment_on_or_above(self, lineno: int) -> str:
        """Comment text attached to a line: same line, else the line above."""
        own = self.comments.get(lineno)
        if own is not None:
            return own
        return self.comments.get(lineno - 1, "")

    def is_suppressed(self, finding: Finding) -> bool:
        for probe in (finding.line, finding.line - 1):
            text = self.comments.get(probe, "")
            if DISABLE_MARKER in text:
                named = text.split(DISABLE_MARKER, 1)[1]
                if finding.rule_id in named:
                    return True
            marker = ESCAPE_MARKERS[finding.rule_id]
            if marker in text:
                return True
        for rule_id, lo, hi in self._block_suppressed:
            if rule_id == finding.rule_id and lo <= finding.line <= hi:
                return True
        return False


class Checker:
    """Plugin base: subclass, set ``rule_id``/``title``, implement ``visit``."""

    rule_id = "RL0"
    title = "abstract checker"

    def visit(self, ctx: Context) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, ctx: Context, node: ast.AST, message: str) -> Finding:
        return Finding(ctx.rel, getattr(node, "lineno", 1), self.rule_id, message)


def _comment_map(source: str) -> dict[int, str]:
    """Map line number -> comment text (without ``#``) for the whole file."""
    out: dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(source).readline):
            if tok.type == tokenize.COMMENT:
                out[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:
        pass
    return out


def _block_suppressions(
    tree: ast.Module, comments: dict[int, str]
) -> list[tuple[str, int, int]]:
    """``# reprolint: disable=RLx`` on a def/class header covers its body."""
    spans: list[tuple[str, int, int]] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            header = comments.get(node.lineno, "")
            if DISABLE_MARKER in header:
                named = header.split(DISABLE_MARKER, 1)[1]
                for rule_id in RULES:
                    if rule_id in named:
                        spans.append((rule_id, node.lineno, node.end_lineno or node.lineno))
    return spans


def iter_py_files(paths: Iterable[str | Path], root: Path) -> Iterator[Path]:
    for raw in paths:
        p = Path(raw)
        if not p.is_absolute():
            p = root / p
        if p.is_file() and p.suffix == ".py":
            yield p
            continue
        if not p.is_dir():
            continue
        for f in sorted(p.rglob("*.py")):
            if any(part in EXCLUDED_DIR_NAMES for part in f.parts):
                continue
            yield f


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return set(data.get("fingerprints", []))


def default_checkers() -> list[Checker]:
    # Imported lazily so `Context`/`Checker` stay importable from fixtures
    # without dragging every checker in.
    from tools.reprolint.checkers import ALL_CHECKERS

    return [cls() for cls in ALL_CHECKERS]


def check_file(
    path: Path, root: Path, checkers: Iterable[Checker] | None = None
) -> list[Finding]:
    """Run checkers over one file, honoring line/block suppressions."""
    rel = str(path.relative_to(root)) if path.is_relative_to(root) else str(path)
    source = path.read_text()
    try:
        ctx = Context(path, rel, source)
    except SyntaxError as exc:
        return [Finding(rel, exc.lineno or 1, "RL0", f"syntax error: {exc.msg}")]
    out: list[Finding] = []
    for checker in checkers if checkers is not None else default_checkers():
        for finding in checker.visit(ctx):
            if not ctx.is_suppressed(finding):
                out.append(finding)
    return sorted(out, key=lambda f: (f.line, f.rule_id))


def run_paths(
    paths: Iterable[str | Path],
    root: Path | None = None,
    baseline: set[str] | None = None,
    checkers: Iterable[Checker] | None = None,
) -> tuple[list[Finding], list[Finding]]:
    """Check all files under ``paths``; return (new, baselined) findings."""
    root = root or Path.cwd()
    baseline = baseline or set()
    checkers = list(checkers) if checkers is not None else default_checkers()
    new: list[Finding] = []
    old: list[Finding] = []
    for f in iter_py_files(paths, root):
        rel_source_lines = None
        for finding in check_file(f, root, checkers):
            if rel_source_lines is None:
                rel_source_lines = f.read_text().splitlines()
            line_text = (
                rel_source_lines[finding.line - 1]
                if 0 < finding.line <= len(rel_source_lines)
                else ""
            )
            (old if finding.fingerprint(line_text) in baseline else new).append(finding)
    return new, old
