"""Dynamic (jaxpr-level) confirmation of RL1/RL2 for the packed engines.

Static analysis sees the Python source; this module checks what XLA actually
traced.  It generalizes the PR 5 jaxpr-inspection test into a reusable
cross-check: trace each packed engine once on a small synthetic instance and
assert the ``lax.while_loop`` body

* contains none of the primitives ``bitops.pack`` / ``unpack`` lower to
  (``reduce_sum`` / ``shift_left`` / ``shift_right_*``) — fused engine only,
* never materializes a bool ``[V, n]`` chi plane
  (``convert_element_type`` to bool with rank >= 2),
* carries ``uint32`` words, not bools, as loop state.

Since ISSUE 8 the edge-list engines (sparse gs / jacobi_packed /
partitioned) get their own body check (:func:`check_edge_body`): ``y``
arrives already packed from the segmented-OR primitive, so the while body
must contain no ``reduce_sum`` (the summing half of ``bitops.pack``) and no
bool-plane convert.  Shifts remain legal there — the word-wise segor
lowering shifts freshly-reduced *words* into place and ``_edge_bits``
extracts single frontier bits; neither is a chi round-trip (DESIGN.md
Sect. 12).

Used two ways: imported by ``tests/test_dualsim_core.py`` (tier-1) and run
standalone in the CI ``reprolint`` job::

    PYTHONPATH=src python -m tools.reprolint.dynamic
"""

from __future__ import annotations

import numpy as np

FUSED_FORBIDDEN = {
    "reduce_sum",  # the sum step of bitops.pack
    "shift_left",  # pack's per-bit shifts
    "shift_right_logical",  # unpack's per-bit shifts
    "shift_right_arithmetic",
}

# Edge-list engines: shifts are load-bearing (bit extraction / word
# assembly on fresh segment-reduce output), but any reduce_sum means a
# bitops.pack snuck back into the sweep.
EDGE_FORBIDDEN = {"reduce_sum"}


def sub_jaxprs(param):
    """Yield jaxprs nested inside an equation parameter."""
    import jax.core as jcore

    if isinstance(param, jcore.ClosedJaxpr):
        yield param.jaxpr
    elif isinstance(param, jcore.Jaxpr):
        yield param
    elif isinstance(param, (tuple, list)):
        for p in param:
            yield from sub_jaxprs(p)


def collect_while_eqns(jaxpr, out=None):
    """All ``while`` equations reachable without entering pallas_call."""
    if out is None:
        out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        if eqn.primitive.name == "while":
            out.append(eqn)
        for param in eqn.params.values():
            for sub in sub_jaxprs(param):
                collect_while_eqns(sub, out)
    return out


def primitive_names(jaxpr, skip=("pallas_call",)):
    """Set of primitive names in a jaxpr, recursing except into ``skip``."""
    names = set()
    for eqn in jaxpr.eqns:
        names.add(eqn.primitive.name)
        if eqn.primitive.name in skip:
            continue
        for param in eqn.params.values():
            for sub in sub_jaxprs(param):
                names |= primitive_names(sub, skip)
    return names


def bool_plane_converts(jaxpr, skip=("pallas_call",)):
    """``convert_element_type`` eqns producing a rank>=2 bool plane."""
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            aval = eqn.outvars[0].aval
            if np.dtype(aval.dtype) == np.dtype(np.bool_) and aval.ndim >= 2:
                out.append(eqn)
        if eqn.primitive.name in skip:
            continue
        for param in eqn.params.values():
            for sub in sub_jaxprs(param):
                out.extend(bool_plane_converts(sub, skip))
    return out


def _while_bodies(fn, *args):
    import jax

    jaxpr = jax.make_jaxpr(fn)(*args)
    whiles = collect_while_eqns(jaxpr.jaxpr)
    return [eqn.params["body_jaxpr"].jaxpr for eqn in whiles]


def check_carried_state(body) -> list[str]:
    """The loop carry must hold packed uint32 words and no bool plane."""
    import jax.numpy as jnp

    violations = []
    carried = [v.aval for v in body.outvars]
    if not any(a.dtype == jnp.uint32 and a.ndim == 2 for a in carried):
        violations.append(f"while carry holds no uint32 word plane: {carried}")
    if any(a.dtype == jnp.bool_ and a.ndim >= 2 for a in carried):
        violations.append(f"while carry holds a bool chi plane: {carried}")
    return violations


def check_fused_body(body) -> list[str]:
    """Fused engine: no pack/unpack primitives, no bool plane, packed carry."""
    violations = check_carried_state(body)
    used = primitive_names(body) & FUSED_FORBIDDEN
    if used:
        violations.append(f"pack/unpack primitives in fused while body: {sorted(used)}")
    converts = bool_plane_converts(body)
    if converts:
        violations.append(
            f"{len(converts)} convert_element_type(bool) plane(s) in fused while body"
        )
    return violations


def check_edge_body(body) -> list[str]:
    """Edge-list engines (ISSUE 8): packed carry, no per-sweep pack
    (``reduce_sum``), no bool chi/y plane anywhere in the while body."""
    violations = check_carried_state(body)
    used = primitive_names(body) & EDGE_FORBIDDEN
    if used:
        violations.append(
            f"per-sweep pack primitives in edge while body: {sorted(used)}"
        )
    converts = bool_plane_converts(body)
    if converts:
        violations.append(
            f"{len(converts)} convert_element_type(bool) plane(s) in edge while body"
        )
    return violations


def check_packed_engines(seed: int = 3) -> list[str]:
    """Trace every packed engine once; return all invariant violations."""
    from repro.core import dualsim, soi
    from repro.data import synth

    violations: list[str] = []

    db = synth.random_graph(70, 2, 200, seed=seed)  # 70 % 32 != 0: pad bits live
    pat = synth.random_pattern(3, 2, 3, seed=seed)
    c = soi.compile_soi(dualsim.pattern_graph_soi(pat), db)
    ops = dualsim.make_packed_operands(c, db)
    bodies = _while_bodies(lambda o: dualsim.solve_packed_fused(o, impl="interpret"), ops)
    if not bodies:
        violations.append("packed_fused: no while_loop found")
    for body in bodies:
        violations.extend(f"packed_fused: {v}" for v in check_fused_body(body))

    db2 = synth.random_graph(48, 2, 120, seed=seed + 1)
    pat2 = synth.random_pattern(3, 2, 3, seed=seed + 1)
    c2 = soi.compile_soi(dualsim.pattern_graph_soi(pat2), db2)
    ops2 = dualsim.make_sparse_operands(c2, db2)
    cases = [
        ("sparse-gs/words", ops2,
         lambda o: dualsim.solve_sparse(o, mode="gs", impl="words")),
        ("sparse-gs/kernel", ops2,
         lambda o: dualsim.solve_sparse(o, mode="gs", impl="kernel")),
        ("jacobi_packed/words", ops2,
         lambda o: dualsim.solve_sparse(o, mode="jacobi_packed", impl="words")),
        ("jacobi_packed/kernel", ops2,
         lambda o: dualsim.solve_sparse(o, mode="jacobi_packed", impl="kernel")),
        ("partitioned", dualsim.make_partitioned_operands(c2, db2, n_blocks=4),
         dualsim.solve_partitioned),
    ]
    for name, case_ops, solve in cases:
        bodies = _while_bodies(solve, case_ops)
        if not bodies:
            violations.append(f"{name}: no while_loop found")
        for body in bodies:
            violations.extend(f"{name}: {v}" for v in check_edge_body(body))
    return violations


def main() -> int:
    violations = check_packed_engines()
    for v in violations:
        print(f"[reprolint.dynamic] {v}")
    if violations:
        print(f"[reprolint.dynamic] {len(violations)} violation(s)")
        return 1
    print("[reprolint.dynamic] all packed engines trace clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
