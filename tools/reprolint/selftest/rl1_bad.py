"""RL1 bad fixture: every trace-safety hazard the rule must catch."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

_TOP = jnp.zeros((4,), dtype=jnp.uint32)  # RL1: module-level jnp constant


@functools.partial(jax.jit, static_argnames=("mode", "opts"))
def solve(chi, mode="gs", opts=[]):  # RL1: unhashable static default
    if chi:  # RL1: Python branch on a tracer
        chi = chi + 1
    n = int(chi)  # RL1: host sync bool/int/float
    host = np.asarray(chi)  # RL1: np.asarray on a traced value
    s = chi.sum().item()  # RL1: .item() host sync
    return chi, n, host, s


def body(state):
    val = helper(state)
    return state + val


def helper(x):
    return float(x)  # RL1: host sync in a while_loop-reachable helper


def run(init):
    return jax.lax.while_loop(lambda s: s.all(), body, init)
