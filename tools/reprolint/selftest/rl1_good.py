"""RL1 good fixture: trace-safe idioms that must stay silent."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

# numpy on purpose: a jnp constant here would initialize the backend early.
_TOP = np.zeros((4,), dtype=np.uint32)


@functools.partial(jax.jit, static_argnames=("mode", "n"))
def solve(chi, mode="gs", n=4):
    if mode == "gs":  # static-argname branch: fine
        chi = chi + 1
    if chi.shape[0] == 0:  # static structure (shape): fine
        return chi
    if chi is None:  # identity comparison: fine
        return jnp.zeros((n,), dtype=jnp.uint32)
    width = int(chi.shape[0])  # host int of static structure: fine
    return chi * width


def host_helper(x):
    # Not jit-reachable: host syncs are legal here.
    return float(np.asarray(x).sum())
