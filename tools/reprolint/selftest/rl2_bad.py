"""RL2 bad fixture: pad-bit violations on packed words outside bitops."""

import jax.numpy as jnp

from repro.core import bitops


def phantom_nodes(flags, n):
    words = bitops.pack(flags)
    comp = ~words  # RL2: unmasked complement turns pad bits on
    total = jnp.sum(words)  # RL2: raw reduction; use bitops.popcount
    blown = words | 0xFFFFFFFF  # RL2: OR with all-ones sets pad bits
    per_row = words.sum(axis=1)  # RL2: raw .sum() on packed words
    return comp, total, blown, per_row
