"""RL2 good fixture: pad-safe handling of packed words."""

from repro.core import bitops


def masked(flags, n):
    words = bitops.pack(flags)
    comp = ~words & bitops.ones_mask(n)  # masked complement: fine
    comp2 = bitops.bnot(words, n)  # sanctioned helper: fine
    narrowed = words & comp2  # AND-only dataflow preserves pad zeros
    total = bitops.popcount(words)  # pad-aware reduction: fine
    flags_back = bitops.unpack(words, n)  # unpack leaves the packed domain
    return comp, narrowed, total, flags_back.sum()
