"""RL3 bad fixture: guarded-field races, await-under-lock, order inversion."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition()
        self.total = 0  # guarded-by: _lock
        self.flushes = 0  # guarded-by: _cv

    def bump(self):
        with self._lock:
            self.total += 1

    def read_torn(self):
        return self.total  # RL3: guarded field read outside its lock

    def order_a(self):
        with self._lock:
            with self._cv:
                self.flushes += 1

    def order_b(self):
        with self._cv:
            with self._lock:  # RL3: inverts order_a's _lock -> _cv order
                self.total += 1

    async def slow_path(self, coro):
        with self._lock:
            await coro  # RL3: await while holding a threading lock
            self.total += 1
