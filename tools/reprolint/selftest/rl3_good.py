"""RL3 good fixture: disciplined lock usage that must stay silent."""

import threading


class Counter:
    def __init__(self):
        self._lock = threading.Lock()
        self._cv = threading.Condition()
        self.total = 0  # guarded-by: _lock
        self.flushes = 0  # guarded-by: _cv

    def bump(self):
        with self._lock:
            self.total += 1

    def read_consistent(self):
        with self._lock:
            return self.total

    def order_a(self):
        with self._lock:
            with self._cv:
                self.flushes += 1

    def order_same(self):
        with self._lock:
            with self._cv:
                self.flushes += 2

    # requires-lock: _lock
    def _bump_locked(self):
        self.total += 1

    async def slow_path(self, coro):
        with self._lock:
            snapshot = self.total
        await coro
        return snapshot
