"""RL4 bad fixture: futures leaked or resolved twice."""


class Server:
    def submit(self, req):
        fut = self._loop.create_future()
        if req.too_big:
            return fut  # RL4: returns with fut unresolved (return is not a discharge)
        self._queue.append(Pending(req, fut))
        return fut

    def double(self):
        fut = self._loop.create_future()
        fut.set_result(1)
        fut.set_result(2)  # RL4: double resolution
        return fut

    def flush(self, items):
        for fut in items:  # rl4: track=fut
            if fut.ready:
                fut._resolve(1)
            # RL4: iteration may end without resolving fut


class Pending:
    def __init__(self, req, future):
        self.req = req
        self.future = future
