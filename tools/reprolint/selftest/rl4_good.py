"""RL4 good fixture: every path discharges the future exactly once."""


class Server:
    def submit(self, req):
        fut = self._loop.create_future()
        if req.too_big:
            fut.set_exception(ValueError("too big"))
            return fut
        self._queue.append(Pending(req, fut))  # handoff: queue owns it now
        return fut

    def flush(self, items):
        for fut in items:  # rl4: track=fut
            try:
                value = self._compute()
            except Exception as exc:
                fut._reject(exc)
            else:
                fut._resolve(value)


class Pending:
    def __init__(self, req, future):
        self.req = req
        self.future = future
