"""Deliberately-dirty RL5 fixture: every exception-hygiene shape, no excuse.

Expected findings (6):
  bare except                                   -> 1
  `except Exception: pass` swallow              -> 1
  `except BaseException: ...` swallow           -> 1
  broad-in-tuple `continue` swallow             -> 1
  dropped `asyncio.create_task(...)` result     -> 2
"""
import asyncio


def eats_everything(step):
    try:
        step()
    except:  # noqa: E722 — the point of the fixture
        print("oops")


def swallows_broad(step):
    try:
        step()
    except Exception:
        pass


def swallows_base_with_ellipsis(step):
    try:
        step()
    except BaseException:
        ...


def swallows_broad_in_tuple(steps):
    for step in steps:
        try:
            step()
        except (ValueError, Exception):
            continue


async def drops_task_handles(coro_fn, loop):
    asyncio.create_task(coro_fn())
    loop.create_task(coro_fn())
