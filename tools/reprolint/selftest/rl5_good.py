"""Corrected twin of ``rl5_bad.py``: every shape RL5 must stay silent on.

Specific exception types may be silently dropped (waiting-with-timeout
idiom), broad handlers must *do* something (record / re-raise), annotated
swallows are the escape hatch, and task handles follow the
``AsyncServer._batch_tasks`` pattern: strong reference + done-callback.
"""
import asyncio


async def waits_out_the_timer(flush):
    try:
        await asyncio.wait_for(flush(), timeout=0.1)
    except asyncio.TimeoutError:
        pass  # flush-timer wait idiom: the timeout IS the signal
    except ValueError:
        pass  # specific type: silence is a documented contract here
    return None


def records_broad_failure(step, log):
    try:
        step()
    except Exception as exc:
        log.append(repr(exc))


def reraises_after_cleanup(step, slot):
    try:
        step()
    except BaseException:
        slot.clear()
        raise


def best_effort_teardown(handles):
    for h in handles:
        try:
            h.close()
        except Exception:
            pass  # rl5: swallow-ok — teardown path, no caller left to tell


async def keeps_task_handles(coro_fn):
    tasks = set()
    t = asyncio.create_task(coro_fn())
    tasks.add(t)
    t.add_done_callback(tasks.discard)
    await asyncio.create_task(coro_fn())
    return tasks
